"""Analytic roofline terms per (arch x shape) cell.

Why analytic: XLA:CPU's ``cost_analysis()`` reports per-device FLOPs with
**while-loop bodies counted once** (verified by calibration in
EXPERIMENTS.md §Methodology), and our step functions are scan-structured
(layers, microbatches, flash-attention blocks), so raw HLO numbers
undercount by the product of trip counts. The roofline therefore uses
the standard MFU-style closed forms below; the raw HLO numbers and the
HLO-parsed collective bytes are recorded alongside as structural
cross-checks (they catch *missing* sharding: an unexpected all-gather
shows up immediately).

Hardware constants (TPU v5e-class, per chip):
  peak 197 TFLOP/s bf16 | 819 GB/s HBM | ~50 GB/s/link ICI (x4 links),
  inter-pod DCI ~ 25 GB/s/chip-pair-equivalent (2x16x16 mesh).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

__all__ = ["roofline_terms", "active_params", "analytic_flops",
           "analytic_hbm_bytes", "analytic_collective_bytes",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW"]


def active_params(cfg: ModelConfig) -> float:
    """Per-token active parameters (MoE: top_k + shared experts only),
    embeddings excluded (standard 6ND accounting)."""
    d = cfg.d_model
    nm = 3 if cfg.mlp_type == "swiglu" else 2
    n = 0.0
    for k in cfg.layer_kinds():
        if k in ("g", "l"):
            n += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
            n += nm * d * cfg.d_ff
        elif k == "m":
            e = cfg.moe
            n += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
            n += (e.top_k + e.n_shared) * nm * d * cfg.d_ff
            n += d * e.n_experts
        elif k == "d":
            n += d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
            n += nm * d * (cfg.moe.d_ff_dense or cfg.d_ff)
        elif k == "r":
            if cfg.family == "rwkv":
                n += 6 * d * d + 2 * d * cfg.d_ff
            else:
                n += 5 * d * d + nm * d * cfg.d_ff
    if cfg.enc_layers:
        n += cfg.enc_layers * (4 * d * d + 2 * d * cfg.d_ff)
        n += cfg.n_layers * (2 * d * d + 2 * d * cfg.kv_dim)  # cross-attn
    return n


def total_params(cfg: ModelConfig) -> float:
    return float(cfg.param_count())


def _attn_flops_per_layer(cfg, b, s, ctx, kind) -> float:
    """Score+PV matmul flops, one layer, forward."""
    if kind == "l":
        ctx = min(ctx, cfg.window)
    return 4.0 * b * s * ctx * cfg.q_dim * 0.5  # causal half


def analytic_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global FLOPs for one lowered step (train: fwd+bwd, no remat
    overhead counted — canonical MFU denominator)."""
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * n_act * tokens
        attn = sum(_attn_flops_per_layer(cfg, shape.global_batch,
                                         shape.seq_len, shape.seq_len, k)
                   for k in cfg.layer_kinds() if k in ("g", "l", "m", "d"))
        f += 3.0 * attn
        f += 6.0 * tokens * cfg.d_model * cfg.vocab_size / 1.0  # lm head
        return f
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        f = 2.0 * n_act * tokens
        attn = sum(_attn_flops_per_layer(cfg, shape.global_batch,
                                         shape.seq_len, shape.seq_len, k)
                   for k in cfg.layer_kinds() if k in ("g", "l", "m", "d"))
        f += attn
        f += 2.0 * tokens * cfg.d_model * cfg.vocab_size
        return f
    # decode: one token per sequence against a shape.seq_len cache
    b = shape.global_batch
    f = 2.0 * n_act * b
    for k in cfg.layer_kinds():
        if k in ("g", "m", "d"):
            f += 4.0 * b * shape.seq_len * cfg.q_dim
        elif k == "l":
            f += 4.0 * b * min(shape.seq_len, cfg.window) * cfg.q_dim
        elif k == "r" and cfg.family == "rwkv":
            f += 4.0 * b * cfg.d_model * cfg.rwkv_head_dim
    f += 2.0 * b * cfg.d_model * cfg.vocab_size
    return f


def _kv_cache_bytes(cfg: ModelConfig, shape: ShapeSpec, dtype_bytes=2):
    b = shape.global_batch
    total = 0
    for k in cfg.layer_kinds():
        if k in ("g", "m", "d"):
            total += 2 * b * shape.seq_len * cfg.kv_dim * dtype_bytes
        elif k == "l":
            total += 2 * b * min(shape.seq_len, cfg.window) * cfg.kv_dim \
                * dtype_bytes
        elif k == "r":
            if cfg.family == "rwkv":
                nh = cfg.d_model // cfg.rwkv_head_dim
                total += b * nh * cfg.rwkv_head_dim ** 2 * dtype_bytes
            else:
                total += b * 4 * cfg.d_model * dtype_bytes
    return total


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeSpec,
                       microbatches: int = 1) -> float:
    """Global HBM traffic for one step (bf16 weights/activations, f32
    optimizer; remat-style activation accounting)."""
    p_total = total_params(cfg)
    w_bytes = p_total * 2
    d = cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        traffic = w_bytes * 2 * microbatches      # fwd + bwd weight reads
        traffic += p_total * 4 * 2                # grad f32 write+read
        traffic += p_total * 4 * 4                # m, v read+write
        traffic += p_total * (2 + 2)              # param read + write
        traffic += tokens * d * 2 * cfg.n_layers * 2   # carries save+read
        return traffic
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return w_bytes + tokens * d * 2 * cfg.n_layers * 2 \
            + _kv_cache_bytes(cfg, shape)
    # decode
    return w_bytes + 2 * _kv_cache_bytes(cfg, shape) \
        + shape.global_batch * d * 2 * cfg.n_layers * 4


def analytic_collective_bytes(cfg: ModelConfig, shape: ShapeSpec,
                              mesh_chips: int = 256, tp: int = 16,
                              microbatches: int = 1) -> float:
    """Per-chip ICI bytes for one step (ring-equivalent accounting:
    all-reduce = 2x payload, RS/AG = 1x each)."""
    p_total = total_params(cfg)
    d = cfg.d_model
    dp = mesh_chips // tp
    if shape.kind in ("train", "prefill"):
        tokens_dev = shape.global_batch * shape.seq_len / dp
        layer_ars = 2 * (2 if shape.kind == "train" else 1)  # attn+mlp
        tp_bytes = layer_ars * cfg.n_layers * tokens_dev * d * 2 * 2
        if shape.kind == "train":
            zero = (p_total * 4 / tp) * 2          # RS grads + AG params
            return tp_bytes + zero
        return tp_bytes
    tokens_dev = shape.global_batch / dp
    return 4 * cfg.n_layers * tokens_dev * d * 2 * 2


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: terms overlap perfectly."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent on the compute roof if perfectly
        overlapped = achievable MFU upper bound for this mapping."""
        return self.compute_s / self.step_s if self.step_s else 0.0


def roofline_terms(cfg: ModelConfig, shape: ShapeSpec, chips: int = 256,
                   tp: int = 16, microbatches: int = 1) -> RooflineTerms:
    f = analytic_flops(cfg, shape) / chips
    m = analytic_hbm_bytes(cfg, shape, microbatches) / chips
    c = analytic_collective_bytes(cfg, shape, chips, tp, microbatches)
    return RooflineTerms(f / PEAK_FLOPS, m / HBM_BW, c / ICI_BW)
