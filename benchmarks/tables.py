"""Paper-table benchmarks (Tables I, II, III + the FA comparison).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``:
``us_per_call`` is the measured wall time of our simulator executing the
algorithm over a batch of crossbar rows (the throughput of the
reproduction itself); ``derived`` carries the paper-facing number
(cycles / memristors / speedups), formatted as ``key=value`` pairs.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.adders import (felix_full_adder_program, full_adder_program,
                               ripple_adder)
from repro.core.baselines import hajali_multiplier, rime_multiplier
from repro.core.bits import from_bits, to_bits
from repro.core.costmodel import ALGOS
from repro.core.executor import run_numpy
from repro.core.matvec import (floatpim_matvec_latency,
                               matvec_area_formula, matvec_latency_formula,
                               floatpim_matvec_area, multpim_mac)
from repro.core.multpim import multpim_multiplier
from repro.core.multpim_area import multpim_area_multiplier

Row = Tuple[str, float, str]


def _time_run(prog, inputs, reps=3) -> float:
    run_numpy(prog, inputs)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        run_numpy(prog, inputs)
    return (time.perf_counter() - t0) / reps * 1e6


def table1_latency(n_values=(16, 32)) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for n in n_values:
        a = rng.integers(0, 1 << min(n, 62), 256)
        b = rng.integers(0, 1 << min(n, 62), 256)
        inp = {"a": to_bits(a, n), "b": to_bits(b, n)}
        for name, maker in [("hajali", hajali_multiplier),
                            ("rime", rime_multiplier),
                            ("multpim", multpim_multiplier)]:
            prog = maker(n)
            out = run_numpy(prog, inp)
            ok = all(int(g) == int(x) * int(y) for g, x, y
                     in zip(from_bits(out["out"]), a, b))
            us = _time_run(prog, inp, reps=1)
            cited = ALGOS[name]["latency"](n)
            rows.append((f"table1/{name}/N={n}", us,
                         f"measured_cycles={prog.n_cycles};cited={cited};"
                         f"exact_match={prog.n_cycles == cited};"
                         f"bitexact={ok}"))
        pa = multpim_area_multiplier(n)
        outa = run_numpy(pa, inp)
        oka = all(int(g) == int(x) * int(y) for g, x, y
                  in zip(from_bits(outa["out"]), a, b))
        rows.append((f"table1/multpim-area/N={n}", 0.0,
                     f"measured_cycles={pa.n_cycles};"
                     f"cited={ALGOS['multpim-area']['latency'](n)};"
                     f"bitexact={oka}"))
        mult = ALGOS["multpim"]["latency"](n)
        rows.append((f"table1/speedup/N={n}", 0.0,
                     f"vs_rime={ALGOS['rime']['latency'](n)/mult:.2f}x;"
                     f"vs_hajali={ALGOS['hajali']['latency'](n)/mult:.2f}x"))
    return rows


def table2_area(n_values=(16, 32)) -> List[Row]:
    rows: List[Row] = []
    for n in n_values:
        for name, maker in [("hajali", hajali_multiplier),
                            ("rime", rime_multiplier),
                            ("multpim", multpim_multiplier)]:
            prog = maker(n)
            rows.append((f"table2/{name}/N={n}", 0.0,
                         f"measured_memristors={prog.n_memristors};"
                         f"cited={ALGOS[name]['area'](n)};"
                         f"partitions={prog.n_partitions}"))
        pa = multpim_area_multiplier(n)
        rows.append((f"table2/multpim-area/N={n}", 0.0,
                     f"measured_memristors={pa.n_memristors};"
                     f"cited={ALGOS['multpim-area']['area'](n)}"))
    return rows


def table3_matvec(n_elems=8, n_bits=32, exec_bits=8, exec_elems=4) -> List[Row]:
    rows: List[Row] = []
    cited_float = floatpim_matvec_latency(n_elems, n_bits)
    cited_mult = matvec_latency_formula(n_elems, n_bits)
    rows.append((f"table3/floatpim/n={n_elems},N={n_bits}", 0.0,
                 f"cited_cycles={cited_float};"
                 f"area_cols={floatpim_matvec_area(1, n_elems, n_bits)[1]}"))
    rows.append((f"table3/multpim/n={n_elems},N={n_bits}", 0.0,
                 f"cited_cycles={cited_mult};"
                 f"area_cols={matvec_area_formula(1, n_elems, n_bits)[1]};"
                 f"speedup={cited_float/cited_mult:.1f}x"))
    # executable verification at reduced width (CPU time):
    rng = np.random.default_rng(1)
    A = rng.integers(0, 1 << (exec_bits - 2), (16, exec_elems))
    x = rng.integers(0, 1 << (exec_bits - 2), exec_elems)
    from repro.engine import get_engine
    t0 = time.perf_counter()
    # paper-parity row: time the raw schedule, not the compiler cache
    # (the `opt` section benchmarks the cached path separately).
    res, cycles = get_engine().matvec(A, x, exec_bits, use_compiler=False)
    us = (time.perf_counter() - t0) * 1e6
    want = A.astype(object) @ x.astype(object)
    ok = all(int(r) == int(w) for r, w in zip(res, want))
    mac = multpim_mac(exec_bits)
    rows.append((f"table3/executable/n={exec_elems},N={exec_bits}", us,
                 f"measured_cycles={cycles};mac_core={mac.n_cycles};"
                 f"paper_per_product={matvec_latency_formula(1, exec_bits)};"
                 f"bitexact={ok}"))
    # co-scheduled executable row: same matvec, K MACs per crossbar
    # pass. The baseline is the *compiled* sequential (k=1) path so the
    # reduction isolates the co-scheduling win from the pass-pipeline
    # savings already counted above.
    k = min(4, exec_elems)
    _, cycles_seq = get_engine().matvec(A, x, exec_bits, k=1)
    t0 = time.perf_counter()
    res_k, cycles_k = get_engine().matvec(A, x, exec_bits, k=k)
    us_k = (time.perf_counter() - t0) * 1e6
    ok_k = all(int(r) == int(w) for r, w in zip(res_k, want))
    passes_seq, passes_k = exec_elems, -(-exec_elems // k)
    rows.append((f"table3/coscheduled/n={exec_elems},N={exec_bits},K={k}",
                 us_k,
                 f"measured_cycles={cycles_k};"
                 f"sequential_cycles={cycles_seq};"
                 f"crossbar_passes={passes_k};sequential_passes={passes_seq};"
                 f"cycles_reduction={cycles_seq / max(cycles_k, 1):.2f}x;"
                 f"bitexact={ok_k}"))
    return rows


def opt_pipeline(n_values=(8, 16, 32)) -> List[Row]:
    """repro.compiler section through the engine API: optimized-vs-raw
    cycles/area for each real program (differentially verified), plus
    compile-once cached matvec throughput vs per-call rebuild."""
    from repro.engine import get_engine
    eng = get_engine()
    rows: List[Row] = []
    for kind, ns in [("multpim", n_values), ("multpim_mac", (8, 16)),
                     ("rime", (8, 16)), ("hajali", (4, 8))]:
        for n in ns:
            e = eng.compile(kind, n).entry
            s = e.stats
            rows.append((f"opt/{kind}/N={n}", 0.0,
                         f"cycles={s.cycles_before}->{s.cycles_after};"
                         f"cols={s.cols_before}->{s.cols_after};"
                         f"inits_removed={s.init_sets_removed};"
                         f"ops_hoisted={s.ops_hoisted};"
                         f"verified={bool(e.verified)}"))
    # list scheduler vs greedy compaction (differentially verified by
    # the compile path), plus the FELIX-gate-set fusion pass on the
    # baselines that allow it.
    from repro.compiler import PassConfig
    for kind, ns in [("multpim", (8, 16)), ("multpim_mac", (8, 16)),
                     ("rime", (8, 16)), ("hajali", (4, 8))]:
        for n in ns:
            e = eng.compile(kind, n, config=PassConfig(scheduler="list"))
            s = e.entry.stats
            rows.append((f"opt/sched/{kind}/N={n}", 0.0,
                         f"list_cycles={s.list_cycles};"
                         f"greedy_cycles={s.greedy_cycles};"
                         f"used={s.scheduler_used};"
                         f"final={s.cycles_after};"
                         f"verified={bool(e.entry.verified)}"))
    for n in (8, 16):
        e = eng.compile("rime", n,
                        config=PassConfig(fuse=True, scheduler="list"))
        s = e.entry.stats
        base = eng.compile("rime", n).entry.stats.cycles_after
        rows.append((f"opt/fuse/rime/N={n}", 0.0,
                     f"cycles={s.cycles_after};baseline={base};"
                     f"ops_fused={s.ops_fused};ops_deleted={s.ops_deleted};"
                     f"verified={bool(e.entry.verified)}"))
    # compile-once cache vs per-call rebuild on repeated matvec traffic.
    # N=16 keeps the per-call program build a substantial fraction of the
    # call; min-of-trials suppresses scheduler noise.
    rng = np.random.default_rng(7)
    nb, ne, reps, trials = 16, 2, 3, 3
    A = rng.integers(0, 1 << (nb - 2), (2, ne))
    x = rng.integers(0, 1 << (nb - 2), ne)
    eng.matvec(A, x, nb)                  # warm the cache / fair start

    def _best(use_compiler):
        best = float("inf")
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(reps):
                res, _ = eng.matvec(A, x, nb, use_compiler=use_compiler)
            best = min(best, (time.perf_counter() - t0) / reps * 1e6)
        return best, res

    us_uncached, res_u = _best(False)
    us_cached, res_c = _best(True)
    ok = all(int(p) == int(q) for p, q in zip(res_u, res_c))
    st = eng.stats()
    rows.append((f"opt/matvec-cache/n={ne},N={nb}", us_cached,
                 f"uncached_us={us_uncached:.0f};cached_us={us_cached:.0f};"
                 f"speedup={us_uncached / max(us_cached, 1e-9):.2f}x;"
                 f"bitexact={ok};cache_hits={st['hits']};"
                 f"cache_entries={st['entries']}"))
    return rows


def fa_comparison() -> List[Row]:
    rows: List[Row] = []
    for name, prog, cited in [
            ("multpim_fa", full_adder_program(False), 5),
            ("multpim_fa_preneg", full_adder_program(True), 4),
            ("felix_fa", felix_full_adder_program(), 6)]:
        compute = sum(1 for c in prog.cycles if not c.is_init)
        rows.append((f"fa/{name}", 0.0,
                     f"measured={compute};cited={cited};"
                     f"gates={'/'.join(sorted(set(prog.gate_histogram())))}"))
    rows.append(("fa/improvement", 0.0,
                 "claim=33%;got={:.0f}%".format(100 * (1 - 4 / 6))))
    for n in (16, 32):
        fast = ripple_adder(n, "multpim")
        slow = ripple_adder(n, "felix")
        rows.append((f"fa/ripple/N={n}", 0.0,
                     f"multpim_cycles={fast.n_cycles};cited=5N={5*n};"
                     f"area={fast.n_memristors};cited_area=3N+5={3*n+5};"
                     f"felix_cycles={slow.n_cycles}"))
    return rows


def time_backends(exe, batch, specs) -> dict:
    """Warm-then-time one ``Executable.run`` per backend spec ->
    ``{spec: seconds}``. The one timing methodology shared by the
    ``throughput`` section and the perf-smoke ``info_*`` metrics, so the
    two can never drift apart."""
    out = {}
    for spec in specs:
        exe.run(batch, backend=spec)          # warm (jit compile)
        t0 = time.perf_counter()
        exe.run(batch, backend=spec)
        out[spec] = time.perf_counter() - t0
    return out


def throughput(rows_list=(1024, 4096), n: int = 16) -> List[Row]:
    """Wall-clock throughput, bit-plane packed vs unpacked: states/sec
    through ``Executable.run`` (marshalling included) on the numpy and
    jax backends, and serve-style cycles-per-MAC *wall time* through the
    co-scheduled MAC group. ``speedup`` on every packed row is measured
    against the **unpacked jax backend** at the same row count — the
    PR-5 acceptance metric (>= 5x at rows >= 1024).

    (Pallas stays out of the wall-clock rows: ``interpret=True`` on CPU
    measures the emulator, not the kernel; its packed parity is covered
    by the test suite and its real-TPU timing is an open ROADMAP item.)
    """
    from repro.engine import get_engine
    eng = get_engine()
    exe = eng.compile("multpim", n)
    rng = np.random.default_rng(5)
    rows: List[Row] = []
    for r_count in rows_list:
        batch = {"a": rng.integers(0, 1 << n, r_count),
                 "b": rng.integers(0, 1 << n, r_count)}
        timings = time_backends(exe, batch, ("jax", "numpy",
                                             "jax:pack=true",
                                             "numpy:pack=true"))
        base = timings["jax"]
        for spec, dt in timings.items():
            rows.append((f"throughput/{spec}/N={n},rows={r_count}",
                         dt * 1e6,
                         f"states_per_s={r_count / dt:.0f};"
                         f"speedup_vs_jax={base / dt:.2f}x;"
                         f"pack={'pack=true' in spec}"))
    # Serve decode traffic: wall time per MAC through the co-scheduled
    # K-MAC group (what the PIM-mode LM head / block projections pay).
    n_mac, r_mac = 8, 1024
    k = eng.effective_coschedule_k("mac", n_mac)
    bex = eng.compile_batch("mac", n_mac, max(k, 1))
    zeros = np.zeros(r_mac, dtype=object)
    group = [eng._mac_inputs(n_mac, rng.integers(0, 1 << (n_mac - 2), r_mac),
                             rng.integers(0, 1 << (n_mac - 2), r_mac),
                             zeros, zeros) for _ in range(bex.k)]
    mac_timings = time_backends(bex, group, ("jax", "jax:pack=true"))
    mac_base = mac_timings["jax"]
    for spec, dt in mac_timings.items():
        us_per_mac = dt * 1e6 / (bex.k * r_mac)
        rows.append((f"throughput/mac-wall/{spec}/N={n_mac},K={bex.k},"
                     f"rows={r_mac}", dt * 1e6,
                     f"us_per_mac={us_per_mac:.3f};"
                     f"macs_per_s={bex.k * r_mac / dt:.0f};"
                     f"speedup_vs_jax={mac_base / dt:.2f}x"))
    return rows


def sim_throughput() -> List[Row]:
    """Simulator throughput: rows/s across executors (numpy / jax scan /
    Pallas interpret) — the reproduction's own perf."""
    from repro.engine import get_engine
    rows: List[Row] = []
    n = 16
    eng = get_engine()
    exe = eng.compile("multpim", n)
    rng = np.random.default_rng(0)
    R = 4096
    batch = {"a": rng.integers(0, 1 << n, R), "b": rng.integers(0, 1 << n, R)}
    for backend in ("numpy", "jax"):
        exe.run(batch, backend=backend)   # warm (jit compile for jax)
        t0 = time.perf_counter()
        exe.run(batch, backend=backend)
        dt = time.perf_counter() - t0
        rows.append((f"sim/{backend}/N={n}", dt * 1e6,
                     f"rows_per_s={R/dt:.0f};mults_per_s={R/dt:.0f}"))
    rows += coschedule_throughput()
    return rows


def coschedule_throughput(n: int = 16, n_elems: int = 8, k: int = 4,
                          rows_m: int = 8) -> List[Row]:
    """Co-scheduled matvec at N=16: crossbar passes and cycles-per-MAC,
    sequential vs K MACs per pass (the PR's headline throughput metric:
    the co-scheduled path must show >= 1.5x fewer cycles per MAC)."""
    from repro.engine import get_engine
    eng = get_engine()
    rows: List[Row] = []
    rng = np.random.default_rng(3)
    A = rng.integers(0, 1 << (n - 2), (rows_m, n_elems))
    x = rng.integers(0, 1 << (n - 2), n_elems)
    t0 = time.perf_counter()
    res_seq, cyc_seq = eng.matvec(A, x, n, k=1)
    us_seq = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    res_k, cyc_k = eng.matvec(A, x, n, k=k)
    us_k = (time.perf_counter() - t0) * 1e6
    ok = all(int(p) == int(q) for p, q in zip(res_seq, res_k))
    passes_seq, passes_k = n_elems, -(-n_elems // k)
    cpm_seq, cpm_k = cyc_seq / n_elems, cyc_k / n_elems
    rows.append((f"sim/coschedule/N={n},n={n_elems},K={k}", us_k,
                 f"cycles_per_mac_seq={cpm_seq:.1f};"
                 f"cycles_per_mac_k={cpm_k:.1f};"
                 f"reduction={cpm_seq / cpm_k:.2f}x;"
                 f"passes_seq={passes_seq};passes_k={passes_k};"
                 f"pass_reduction={passes_seq / passes_k:.1f}x;"
                 f"seq_us={us_seq:.0f};bitexact={ok}"))
    bex = eng.compile_batch("mac", n, k)
    cost = bex.cost()
    rows.append((f"sim/coschedule-cost/N={n},K={k}", 0.0,
                 f"cycles_per_pass={cost.cycles};"
                 f"cycles_per_mac={cost.cycles_per_program:.1f};"
                 f"memristors={cost.memristors};"
                 f"partitions={cost.partitions}"))
    return rows


def resident_chain(n: int = 8, rows_m: int = 64,
                   n_elems: int = 8) -> List[Row]:
    """Device-resident carry-save chains vs the per-pass host
    round-trip they replaced: wall time of the same inner product on
    each packed backend (state stays packed on device for the whole MAC
    chain, one pack in + one drain out), plus the compiled
    stage/recomb micro-program cycles against the analytic budgets the
    cycle model used to charge."""
    from repro.core.matvec import STAGING_CYCLES
    from repro.engine import Engine
    rows: List[Row] = []
    rng = np.random.default_rng(5)
    A = rng.integers(0, 1 << (n - 2), (rows_m, n_elems))
    X = rng.integers(0, 1 << (n - 2), (rows_m, n_elems))
    for spec in ("numpy:pack=true", "jax:pack=true"):
        eng = Engine(spec)
        eng.inner_product(A, X, n, k=1, resident=True)   # warm/jit
        eng.inner_product(A, X, n, k=1, resident=False)
        t0 = time.perf_counter()
        res, _ = eng.inner_product(A, X, n, k=1, resident=True)
        us_res = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        rt, _ = eng.inner_product(A, X, n, k=1, resident=False)
        us_rt = (time.perf_counter() - t0) * 1e6
        ok = all(int(p) == int(q) for p, q in zip(res, rt))
        rows.append((f"resident/{spec}/N={n},rows={rows_m},E={n_elems}",
                     us_res,
                     f"roundtrip_us={us_rt:.0f};"
                     f"speedup={us_rt / max(us_res, 1e-9):.2f}x;"
                     f"bitexact={ok}"))
    eng = Engine("numpy:pack=true")
    rows.append((f"resident/cycles/N={n}", 0.0,
                 f"stage_measured={eng.staging_cycles(n)};"
                 f"stage_analytic={STAGING_CYCLES(n)};"
                 f"recomb_measured={eng.recomb_cycles(n)};"
                 f"recomb_analytic={5 * 2 * n}"))
    return rows


def serve_load(n_requests: int = 32, rate: float = 500.0,
               n_bits: int = 8) -> List[Row]:
    """Continuous-batching serve scheduler under seeded Poisson load
    (repro.serve): one row per scheduling mode — us/token as the timed
    column, tokens/sec plus steady-state TTFT / per-token latency
    percentiles in the derived column — and a speedup row comparing
    continuous batching against per-pass host round-trip and serial
    one-request-at-a-time replays of the same trace (the acceptance
    gates watch >= 3x over serial, >= 2x over round-trip)."""
    from repro.engine import get_engine
    from repro.serve import TrafficConfig, compare_modes, generate
    eng = get_engine()
    cfg = TrafficConfig(n_requests=n_requests, rate=rate, n_bits=n_bits)
    res = compare_modes(eng, generate(cfg), backend="jax:pack=true")
    rows: List[Row] = []
    for mode in ("continuous", "roundtrip", "serial"):
        rep = res[mode]
        s = rep.summary()
        rows.append((f"serve_load/{mode}/n={n_requests}",
                     rep.wall_s * 1e6 / max(1, rep.n_tokens),
                     f"tokens_per_s={s['tokens_per_s']:.1f};"
                     f"ttft_p50_us={s['ttft_p50_us']:.0f};"
                     f"ttft_p99_us={s['ttft_p99_us']:.0f};"
                     f"token_p50_us={s['token_p50_us']:.0f};"
                     f"token_p99_us={s['token_p99_us']:.0f};"
                     f"passes={s['passes']};"
                     f"recompiles={s['recompiles']};"
                     f"bitexact={s['bit_exact']}"))
    rows.append((f"serve_load/speedup/n={n_requests}", 0.0,
                 f"speedup={res['speedup']:.2f}x;"
                 f"resident_speedup={res['resident_speedup']:.2f}x;"
                 f"tokens_match={res['tokens_match']}"))
    return rows


def pim_plan_sweep() -> List[Row]:
    """Beyond-paper: Section-VI crossbar offload plan for every assigned
    architecture (per-token serving latency, crossbar count, energy
    proxy, speedup over a FloatPIM-style mapping)."""
    from repro.configs import ARCHS
    from repro.pim import gemms_from_config, plan_model
    rows: List[Row] = []
    for name, cfg in ARCHS.items():
        plan = plan_model(gemms_from_config(cfg, batch_tokens=1), n_bits=8)
        energy_uj = sum(g["energy_uj"] for g in plan.per_gemm)
        rows.append((f"pim_plan/{name}", 0.0,
                     f"cycles_per_token={plan.total_cycles};"
                     f"latency_us={plan.latency_us:.0f};"
                     f"crossbars={plan.total_crossbars};"
                     f"memristors_G={plan.total_memristors/1e9:.1f};"
                     f"energy_uJ={energy_uj:.0f};"
                     f"speedup_vs_floatpim={plan.speedup_vs_floatpim:.1f}x"))
    return rows


def block_pim_plan(archs=("gemma2-9b", "deepseek-moe-16b")) -> List[Row]:
    """Full-block PIM serving (--pim-scope full): every linear of a
    transformer block lowered onto heterogeneous co-scheduled crossbar
    groups (repro.pim.plan_block). One row per (arch, scope) with the
    scope's cycles-per-MAC — the FFN rows are the headline metric the
    PR-4 perf tracking watches — plus an end-to-end cycles/token row."""
    import dataclasses

    from repro.configs import get_config
    from repro.engine import Engine
    from repro.pim import plan_block
    rows: List[Row] = []
    eng = Engine()
    for arch in archs:
        cfg = dataclasses.replace(get_config(arch),
                                  pim_linear_mode="pim",
                                  pim_block_mode="full")
        plan = plan_block(cfg, eng)
        for scope, m in plan.scope_metrics().items():
            rows.append((f"block_pim/{arch}/{scope}", 0.0,
                         f"cycles_per_mac={m['cycles_per_mac']:.2f};"
                         f"macs_per_pass={m['macs_per_pass']};"
                         f"pass_cycles={m['pass_cycles']};"
                         f"chains={'/'.join(map(str, m['chains']))};"
                         f"crossbars={m['crossbars']};"
                         f"passes_per_token={m['passes_per_token']};"
                         f"cycles_per_token={m['cycles_per_token']};"
                         f"row_util={m['row_utilization']:.2f}"))
        rows.append((f"block_pim/{arch}/total", 0.0,
                     f"cycles_per_token={plan.cycles_per_token};"
                     f"groups={len(plan.groups)}"))
    return rows


def device_hierarchy(arch: str = "gemma2-9b",
                     shape: str = "2x2x4x4",
                     target_tokens_per_sec: float = 1e5) -> List[Row]:
    """Device-hierarchy cost rollup (repro.device): the full-block plan
    placed onto a PIM chip, its modeled command trace charged through
    the hierarchical cost model. Emits a degeneracy row (a 1x1x1x1
    device must reproduce the flat plan's cycles/token exactly), one
    utilization row per hierarchy level of ``shape``, a totals row
    (end-to-end latency / energy / tokens-per-sec with hop + host-link
    terms the flat model cannot see), and the fleet-sizing answer:
    devices needed to sustain ``target_tokens_per_sec`` aggregate."""
    import dataclasses

    from repro.configs import get_config
    from repro.device import CoordAllocator, DeviceConfig, block_trace, charge
    from repro.engine import Engine
    from repro.pim import plan_block
    rows: List[Row] = []
    eng = Engine()
    cfg = dataclasses.replace(get_config(arch), pim_linear_mode="pim",
                              pim_block_mode="full")

    # Degeneracy: one crossbar, one group -> zero hops, critical path ==
    # the flat plan's cycles/token (same invariant tests/test_device.py
    # property-tests).
    one = DeviceConfig.parse("1x1x1x1", crossbar=eng.crossbar)
    head = plan_block(cfg, eng, scopes=("head",))
    rep1 = charge(block_trace(head, one))
    rows.append((f"device/degenerate/{arch}/1x1x1x1", 0.0,
                 f"crit_cycles={rep1.crit_cycles};"
                 f"flat_cycles={head.cycles_per_token};"
                 f"exact_match={rep1.crit_cycles == head.cycles_per_token};"
                 f"hop_ns={rep1.hop_ns:.0f}"))

    # Full-block plan placed onto the hierarchy (scope-aligned banks).
    dev = DeviceConfig.parse(shape, crossbar=eng.crossbar)
    t0 = time.perf_counter()
    plan = plan_block(cfg, eng, placer=CoordAllocator(dev).place)
    rep = charge(block_trace(plan, dev))
    us = (time.perf_counter() - t0) * 1e6
    for lv in rep.levels:
        rows.append((f"device/level/{arch}/{shape}/{lv['level']}", 0.0,
                     f"units={lv['units']};used={lv['used']};"
                     f"busy_cycles={lv['busy_cycles']};"
                     f"utilization={lv['utilization']:.3f}"))
    rows.append((f"device/total/{arch}/{shape}", us,
                 f"crit_cycles={rep.crit_cycles};"
                 f"compute_us={rep.compute_us:.1f};"
                 f"hop_ns={rep.hop_ns:.0f};"
                 f"transfer_us={rep.transfer_us:.2f};"
                 f"latency_us={rep.latency_us:.1f};"
                 f"energy_uJ={rep.energy_uj:.1f};"
                 f"row_energy_uJ={rep.row_energy_uj:.1f};"
                 f"tokens_per_s={rep.tokens_per_sec:.1f}"))
    rows.append((f"device/fleet/{arch}/{shape}", 0.0,
                 f"target_tokens_per_s={target_tokens_per_sec:.0f};"
                 f"tokens_per_s_per_device={rep.tokens_per_sec:.1f};"
                 f"n_devices={rep.capacity(target_tokens_per_sec)}"))
    return rows


def obs_metrics(n: int = 16) -> List[Row]:
    """Observability section: tracer overhead (the disabled hot path
    must be ~free), end-to-end ``Executable.run`` wall time with tracing
    off vs on, and the switching-activity energy proxy
    (``ExecCost.energy_proxy``) for multpim vs rime at N=16."""
    from repro import obs
    from repro.engine import get_engine

    from repro.obs.trace import Tracer

    rows: List[Row] = []
    # Disabled-path span cost — the price every instrumented call site
    # pays in production (span() returns the shared NULL_SPAN, so this
    # is one enabled-flag check + one no-op context manager). A local
    # Tracer keeps the micro-bench's 20k events out of any session
    # trace (--trace) and the global tracer's state untouched.
    t = Tracer()
    reps = 200_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with t.span("bench.noop"):
            pass
    ns_disabled = (time.perf_counter() - t0) / reps * 1e9
    t.enable()
    reps_on = 20_000
    t0 = time.perf_counter()
    for _ in range(reps_on):
        with t.span("bench.noop"):
            pass
    ns_enabled = (time.perf_counter() - t0) / reps_on * 1e9
    rows.append(("obs/span-overhead", 0.0,
                 f"disabled_ns={ns_disabled:.0f};"
                 f"enabled_ns={ns_enabled:.0f}"))
    # End-to-end overhead: best-of-trials run wall time with tracing
    # off vs on. The acceptance bar is <1% disabled overhead; the off
    # timing *is* the disabled path (instrumentation always present).
    eng = get_engine()
    exe = eng.compile("multpim", n)
    rng = np.random.default_rng(11)
    R = 2048
    batch = {"a": rng.integers(0, 1 << n, R),
             "b": rng.integers(0, 1 << n, R)}
    spec = "numpy:pack=true"
    exe.run(batch, backend=spec)              # warm

    def _best_run() -> float:
        best = float("inf")
        for _ in range(5):
            t0 = time.perf_counter()
            exe.run(batch, backend=spec)
            best = min(best, time.perf_counter() - t0)
        return best

    was_enabled = obs.enabled()
    obs.disable()
    off = _best_run()
    obs.enable()
    on = _best_run()
    if not was_enabled:
        obs.disable()
    rows.append((f"obs/run-overhead/N={n},rows={R}", off * 1e6,
                 f"disabled_us={off * 1e6:.0f};enabled_us={on * 1e6:.0f};"
                 f"enabled_overhead_pct={(on / off - 1) * 100:.1f}"))
    # Switching-activity energy proxy: mean memristor bit flips per
    # crossbar row per multiplication — the data-transition counterpart
    # of energy_table()'s every-gate-charged pJ model.
    for kind in ("multpim", "rime"):
        c = eng.compile(kind, n).cost()
        rows.append((f"obs/energy-proxy/{kind}/N={n}", 0.0,
                     f"bit_flips_per_row={c.energy_proxy:.1f};"
                     f"cycles={c.cycles};"
                     f"energy_pJ={c.energy_uj * 1e6:.1f}"))
    return rows


def faults_table(n_values=(4, 8), rates=(0.0, 1e-5, 1e-4, 1e-3),
                 rows_m: int = 32, n_elems: int = 8,
                 spec: str = "jax:pack=true") -> List[Row]:
    """Accuracy under injected device errors (repro.faults): the same
    ``rows_m``-lane resident MAC chain driven at each transient
    flip rate x operand width, with drain-time detection + bounded
    replay recovery on and off. ``accuracy`` is the fraction of lanes
    whose drained inner product matches the plain-int reference — the
    curve the reliability section of the docs plots: detection-on stays
    at (or near) 1.0 well past the rate where detection-off has already
    lost lanes, until the unrecoverable regime where stuck replay
    transients outrun the retry budget (those lanes are what serve-side
    quarantine absorbs)."""
    from repro import obs
    from repro.engine import get_engine
    from repro.faults import get_fault_model
    rows: List[Row] = []
    eng = get_engine()
    rng = np.random.default_rng(17)
    for n in n_values:
        mask = (1 << (2 * n)) - 1
        A = rng.integers(0, 1 << (n - 2), (rows_m, n_elems))
        X = rng.integers(0, 1 << (n - 2), (rows_m, n_elems))
        want = [int(sum(int(a) * int(x) for a, x in zip(ar, xr))) & mask
                for ar, xr in zip(A, X)]
        none = np.zeros(rows_m, dtype=bool)
        for rate in rates:
            for detect in ((True, False) if rate else (True,)):
                if rate:
                    fspec = f"flip@{rate:g}@3"
                    backend = f"{spec},faults={fspec}"
                    get_fault_model(fspec).reset()
                else:
                    backend = spec
                c0 = dict(obs.dump()["counters"])
                rex = eng.resident(n, rows=rows_m, backend=backend,
                                   detect=detect)
                t0 = time.perf_counter()
                for e in range(n_elems):
                    rex.step(A[:, e], X[:, e],
                             fresh=None if e == 0 else none)
                got = [int(v) for v in rex.drain()]
                us = (time.perf_counter() - t0) * 1e6
                c1 = obs.dump()["counters"]
                d = lambda k: c1.get(k, 0) - c0.get(k, 0)  # noqa: E731
                acc = sum(g == w for g, w in zip(got, want)) / rows_m
                rows.append((
                    f"faults/N={n},rate={rate:g},"
                    f"detect={'on' if detect else 'off'}", us,
                    f"accuracy={acc:.4f};rows={rows_m};elems={n_elems};"
                    f"injected={d('faults.injected')};"
                    f"detected={d('faults.detected')};"
                    f"recovered={d('faults.recovered')};"
                    f"unrecovered_lanes={int(rex.unrecovered.sum())};"
                    f"replayed_passes={d('faults.replayed_passes')}"))
    return rows


def energy_table(n_values=(16, 32)) -> List[Row]:
    """Beyond-paper: per-multiplication energy proxy (gate activations x
    pJ/gate) — the axis RIME optimizes for; MultPIM wins it too because
    energy scales with cycles x active partitions."""
    from repro.core.costmodel import CrossbarSpec
    spec = CrossbarSpec()
    rows: List[Row] = []
    for n in n_values:
        for name, maker in [("hajali", hajali_multiplier),
                            ("rime", rime_multiplier),
                            ("multpim", multpim_multiplier),
                            ("multpim-area", multpim_area_multiplier)]:
            prog = maker(n)
            gates = sum(len(c.ops) for c in prog.cycles)
            inits = sum(len(c.init_cells) for c in prog.cycles)
            pj = (gates + 0.5 * inits) * spec.energy_pj_per_gate
            rows.append((f"energy/{name}/N={n}", 0.0,
                         f"gate_ops={gates};init_sets={inits};"
                         f"energy_pJ={pj:.1f}"))
    return rows
