"""Benchmark harness: one section per paper table + roofline extraction.

Prints ``name,us_per_call,derived`` CSV (the harness contract).

  PYTHONPATH=src python -m benchmarks.run [--section table1|table2|table3|
                                           fa|opt|sim|roofline|all]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()

    from . import tables
    from .roofline import roofline_rows

    sections = {
        "table1": tables.table1_latency,
        "table2": tables.table2_area,
        "table3": tables.table3_matvec,
        "fa": tables.fa_comparison,
        "opt": tables.opt_pipeline,
        "sim": tables.sim_throughput,
        "pim_plan": tables.pim_plan_sweep,
        "energy": tables.energy_table,
        "roofline": lambda: roofline_rows(args.dryrun_json),
    }
    names = list(sections) if args.section == "all" else [args.section]
    print("name,us_per_call,derived")
    bad = 0
    for name in names:
        try:
            for row in sections[name]():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:    # noqa: BLE001
            bad += 1
            print(f"{name},0.0,ERROR={e!r}", file=sys.stderr)
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
