"""Benchmark harness: one section per paper table + roofline extraction.

Prints ``name,us_per_call,derived`` CSV (the harness contract) and, so
the perf trajectory is tracked across PRs, writes a machine-readable
JSON (``--json``, default ``BENCH_pr10.json``) mapping each section to
its rows::

    {"sections": {"table1": [[name, us_per_call, derived], ...], ...},
     "errors": {"section": "repr(exc)"}}

  PYTHONPATH=src python -m benchmarks.run [--section table1|table2|table3|
                                           fa|opt|sim|throughput|resident|
                                           block_pim|serve_load|device|
                                           faults|obs|roofline|all|
                                           sec1,sec2,...]
                                          [--json BENCH_pr10.json|off]
                                          [--trace OUT.json]
                                          [--metrics OUT.json]
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    ap.add_argument("--json", default="BENCH_pr10.json",
                    help="machine-readable output path ('off' disables)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="enable span tracing and write a Chrome "
                         "trace-event file at exit")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="write the obs metrics snapshot as JSON")
    args = ap.parse_args()

    from repro import obs
    if args.trace:
        obs.enable()

    from . import tables
    from .roofline import roofline_rows

    sections = {
        "table1": tables.table1_latency,
        "table2": tables.table2_area,
        "table3": tables.table3_matvec,
        "fa": tables.fa_comparison,
        "opt": tables.opt_pipeline,
        "sim": tables.sim_throughput,
        "throughput": tables.throughput,
        "resident": tables.resident_chain,
        "pim_plan": tables.pim_plan_sweep,
        "block_pim": tables.block_pim_plan,
        "serve_load": tables.serve_load,
        "device": tables.device_hierarchy,
        "faults": tables.faults_table,
        "energy": tables.energy_table,
        "obs": tables.obs_metrics,
        "roofline": lambda: roofline_rows(args.dryrun_json),
    }
    names = (list(sections) if args.section == "all"
             else args.section.split(","))
    print("name,us_per_call,derived")
    collected = {}
    errors = {}
    for name in names:
        try:
            rows = sections[name]()
            collected[name] = [[r[0], r[1], r[2]] for r in rows]
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception as e:    # noqa: BLE001
            errors[name] = repr(e)
            print(f"{name},0.0,ERROR={e!r}", file=sys.stderr)
    if args.json != "off":
        with open(args.json, "w") as f:
            json.dump({"sections": collected, "errors": errors}, f, indent=1)
        print(f"wrote {args.json} ({len(collected)} sections)",
              file=sys.stderr)
    if args.trace:
        n_ev = obs.export_trace(args.trace)
        print(f"trace: {n_ev} events -> {args.trace}", file=sys.stderr)
    if args.metrics:
        obs.write_metrics(args.metrics)
        print(f"metrics snapshot -> {args.metrics}", file=sys.stderr)
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
