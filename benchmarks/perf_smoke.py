"""Perf smoke gate: fail CI when cycles-per-MAC (or any tracked cycle
count) regresses more than 5% against the checked-in baseline.

Most gated metrics are *deterministic compiler outputs* (cycle counts
from the opt / sim_throughput benchmark paths at small N), not
wall-clock, so the gate is immune to runner noise while still catching
real scheduling or co-scheduling regressions; those gate at the tight
``TOLERANCE``. Wall-clock throughput of the bit-plane packed backends
(``wall_*`` metrics, introduced as ``info_*`` one baseline ago) is now
in the baseline too, gated at the deliberately generous
``WALL_TOLERANCE`` — it only catches gross regressions (a packed
backend silently falling off its fast path), never CI-runner noise.
Ratios like ``info_packed_speedup_vs_jax`` stay informational: both
sides of a ratio move with the machine, so no tolerance is defensible.

  PYTHONPATH=src python -m benchmarks.perf_smoke                 # gate
  PYTHONPATH=src python -m benchmarks.perf_smoke --write-baseline

The serve-load scenario (seeded Poisson trace through the
:mod:`repro.serve` continuous batcher, replayed continuous vs serial)
contributes ``wall_`` per-token throughput/latency metrics and hard
in-process asserts: zero recompiles after warmup and bit-identical
tokens across schedules.

Baseline lives at ``benchmarks/baseline_pr10.json``; regenerate it (and
review the diff!) whenever a change legitimately improves or trades off
these numbers.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "baseline_pr10.json")
TOLERANCE = 0.05          # >5% regression fails (deterministic cycles)
WALL_PREFIX = "wall_"     # wall-clock: gated, but loosely
WALL_TOLERANCE = 1.0      # >2x regression fails (absorbs runner noise)
INFO_PREFIX = "info_"     # reported, never gated


def collect_metrics(n: int = 8, k: int = 4, n_elems: int = 8) -> dict:
    """Deterministic cycle metrics at small N (fast enough for CI)."""
    import numpy as np

    from repro.compiler import PassConfig
    from repro.engine import get_engine

    eng = get_engine()
    rng = np.random.default_rng(0)
    A = rng.integers(0, 1 << (n - 2), (4, n_elems))
    x = rng.integers(0, 1 << (n - 2), n_elems)
    res_seq, cyc_seq = eng.matvec(A, x, n, k=1)
    res_k, cyc_k = eng.matvec(A, x, n, k=k)
    assert [int(a) for a in res_seq] == [int(b) for b in res_k], \
        "co-scheduled matvec diverged from sequential"

    bex = eng.compile_batch("mac", n, k)
    listed = eng.compile("multpim", n,
                         config=PassConfig(scheduler="list")).entry.stats
    rime_list = eng.compile("rime", n,
                            config=PassConfig(scheduler="list")).entry.stats
    rime_fuse = eng.compile(
        "rime", n, config=PassConfig(fuse=True,
                                     scheduler="list")).entry.stats

    # Heterogeneous co-scheduled groups (the full-block serving path):
    # a mixed [2x mac, multiply] group must merge with no cycle blowup,
    # and the block planner's per-scope cycles-per-MAC must hold.
    import dataclasses

    from repro.configs import get_config
    from repro.pim import plan_block
    gex = eng.compile_group([("mac", n, 2), ("multpim", n)])
    cfg = dataclasses.replace(get_config("gemma2-9b"),
                              pim_linear_mode="pim", pim_block_mode="full")
    scope = plan_block(cfg, eng).scope_metrics()

    # Wall-clock throughput, packed vs unpacked (gated at
    # WALL_TOLERANCE — see module docstring): lower-is-better
    # us-per-1k-states through Executable.run at a serve-sized batch.
    # The timing loop is benchmarks.tables.time_backends — the same
    # methodology as the `throughput` section, just a narrower spec
    # list and one row count, so smoke stays fast.
    from benchmarks.tables import time_backends
    exe = eng.compile("multpim", 16)
    rows = 1024
    tbatch = {"a": rng.integers(0, 1 << 16, rows),
              "b": rng.integers(0, 1 << 16, rows)}
    wall = time_backends(exe, tbatch, ("jax", "jax:pack=true",
                                       "numpy:pack=true"))

    # Serve load scenario: seeded Poisson trace through the continuous
    # batcher, replayed under resident continuous batching, the per-pass
    # host round-trip it replaced, and serial scheduling — on the packed
    # jax backend (the device backend the resident gate targets).
    # Correctness invariants (zero recompiles after warmup,
    # bit-identical tokens across all three schedules, resident actually
    # beating round-trip) assert hard here; throughput/latency gate as
    # wall_*.
    from repro.serve import TrafficConfig, compare_modes, generate
    tcfg = TrafficConfig(n_requests=32, rate=500.0, n_bits=n, seed=0)
    res = compare_modes(eng, generate(tcfg), backend="jax:pack=true")
    cont = res["continuous"]
    assert cont.recompiles == 0, \
        f"serve steady state recompiled {cont.recompiles}x"
    assert res["tokens_match"], \
        "scheduling/substrate changed emitted tokens"
    assert res["resident_speedup"] >= 2.0, \
        f"resident serve only {res['resident_speedup']:.2f}x over " \
        f"round-trip (gate: 2x)"

    return {
        # lower is better for every metric here
        f"stage_cycles_n{n}": eng.staging_cycles(n),
        f"recomb_cycles_n{n}": eng.recomb_cycles(n),
        f"recomb_cycles_n{2 * n}": eng.recomb_cycles(2 * n),
        f"cycles_per_mac_seq_n{n}": cyc_seq / n_elems,
        f"cycles_per_mac_k{k}_n{n}": cyc_k / n_elems,
        f"coschedule_pass_cycles_k{k}_n{n}": bex.n_cycles,
        f"mac_cycles_n{n}": eng.compile("mac", n).n_cycles,
        f"multpim_cycles_n{n}": listed.cycles_after,
        f"multpim_list_cycles_n{n}": listed.list_cycles,
        f"rime_cycles_n{n}": rime_list.cycles_after,
        f"rime_fuse_list_cycles_n{n}": rime_fuse.cycles_after,
        f"group_hetero_pass_cycles_n{n}": gex.n_cycles,
        f"block_ffn_cycles_per_mac_n{n}": scope["ffn"]["cycles_per_mac"],
        f"block_attn_cycles_per_mac_n{n}": scope["attn"]["cycles_per_mac"],
        f"block_full_cycles_per_token_n{n}": float(
            sum(m["cycles_per_token"] for m in scope.values())),
        # wall-clock (gated at WALL_TOLERANCE, lower is better)
        "wall_us_per_1k_states_jax": wall["jax"] * 1e6 / (rows / 1e3),
        "wall_us_per_1k_states_jax_packed":
            wall["jax:pack=true"] * 1e6 / (rows / 1e3),
        "wall_us_per_1k_states_numpy_packed":
            wall["numpy:pack=true"] * 1e6 / (rows / 1e3),
        "wall_us_per_token_serve_continuous":
            cont.wall_s * 1e6 / max(1, cont.n_tokens),
        "wall_serve_p99_token_latency_us":
            cont.token_latency_us.get("p99", 0.0),
        # informational ratios (never gated, never in the baseline)
        "info_packed_speedup_vs_jax":
            wall["jax"] / wall["jax:pack=true"],
        "info_serve_speedup_vs_serial": res["speedup"],
        "info_serve_resident_speedup_vs_roundtrip":
            res["resident_speedup"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args()

    metrics = collect_metrics()
    for name, val in sorted(metrics.items()):
        print(f"{name} = {val:.2f}")

    if args.write_baseline:
        gated = {k: round(v, 4) for k, v in metrics.items()
                 if not k.startswith(INFO_PREFIX)}
        with open(args.baseline, "w") as f:
            json.dump(gated, f, indent=1, sort_keys=True)
        print(f"wrote baseline {args.baseline} "
              f"({len(gated)} gated metrics)")
        return

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = []
    for name, base in sorted(baseline.items()):
        if name not in metrics:
            failures.append(f"{name}: metric disappeared "
                            f"(baseline {base})")
            continue
        got = metrics[name]
        tol = (WALL_TOLERANCE if name.startswith(WALL_PREFIX)
               else args.tolerance)
        if got > base * (1 + tol):
            failures.append(
                f"{name}: {got:.2f} vs baseline {base:.2f} "
                f"(+{100 * (got / base - 1):.1f}%, limit "
                f"+{100 * tol:.0f}%)")
    for name in sorted(set(metrics) - set(baseline)):
        if not name.startswith(INFO_PREFIX):
            print(f"note: new metric '{name}' not in baseline")
    if failures:
        print("PERF SMOKE FAILED:")
        for f in failures:
            print("  " + f)
        sys.exit(1)
    print(f"perf smoke OK ({len(baseline)} metrics within "
          f"{100 * args.tolerance:.0f}% of baseline)")


if __name__ == "__main__":
    main()
