"""Roofline extraction: analytic three-term model per dry-run cell,
cross-checked against the compiled artifact's cost/memory analysis.

Reads ``dryrun_results.json`` (produced by ``repro.launch.dryrun``) and
emits one row per (arch x shape) on the single-pod mesh with:

  compute_s / memory_s / collective_s   (seconds, per step)
  dominant term, achievable-MFU bound, MODEL_FLOPS/HLO ratio note,
  per-device memory fit vs the 16 GB HBM budget.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

from repro.configs import ARCHS, SHAPES

from .analytic import analytic_flops, roofline_terms

Row = Tuple[str, float, str]

MICROBATCHES = {"train_4k": 8}


def load_dryrun(path: str = "dryrun_results.json") -> Dict:
    if not os.path.exists(path):
        return {}
    out = {}
    for rec in json.load(open(path)):
        if rec.get("status") == "ok":
            out[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return out


def roofline_rows(dryrun_path: str = "dryrun_results.json") -> List[Row]:
    rows: List[Row] = []
    dr = load_dryrun(dryrun_path)
    chips = 256
    for arch, cfg in ARCHS.items():
        for shape in SHAPES:
            from repro.configs.shapes import shape_applicable
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            mb = MICROBATCHES.get(shape.name, 1)
            terms = roofline_terms(cfg, shape, chips=chips, tp=16,
                                   microbatches=mb)
            rec = dr.get((arch, shape.name, "16x16"))
            extra = ""
            if rec:
                hlo_flops_dev = rec["flops"]
                model_dev = analytic_flops(cfg, shape) / chips
                peak = rec["per_device"]["peak_bytes"] / 2 ** 30
                coll = sum(rec["collective_bytes"].values())
                extra = (f";hlo_flops_dev={hlo_flops_dev:.3e}"
                         f";hlo_coll_bytes={coll:.3e}"
                         f";peak_gib={peak:.1f}"
                         f";fits_16g={peak < 16.0}")
            rows.append((
                f"roofline/{arch}/{shape.name}", 0.0,
                f"compute_s={terms.compute_s:.4e}"
                f";memory_s={terms.memory_s:.4e}"
                f";collective_s={terms.collective_s:.4e}"
                f";dominant={terms.dominant}"
                f";mfu_bound={terms.roofline_fraction:.2f}" + extra))
    return rows


def summary_table(dryrun_path: str = "dryrun_results.json") -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    dr = load_dryrun(dryrun_path)
    chips = 256
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| MFU bound | peak GiB/chip | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|"]
    for arch, cfg in ARCHS.items():
        for shape in SHAPES:
            from repro.configs.shapes import shape_applicable
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            mb = MICROBATCHES.get(shape.name, 1)
            t = roofline_terms(cfg, shape, chips=chips, tp=16,
                               microbatches=mb)
            rec = dr.get((arch, shape.name, "16x16"))
            peak = (rec["per_device"]["peak_bytes"] / 2 ** 30
                    if rec else float("nan"))
            lines.append(
                f"| {arch} | {shape.name} | {t.compute_s:.3e} "
                f"| {t.memory_s:.3e} | {t.collective_s:.3e} "
                f"| {t.dominant} | {t.roofline_fraction:.2f} "
                f"| {peak:.1f} | {'yes' if peak < 16 else 'NO'} |")
    return "\n".join(lines)
